//! Steady-state rounds of the sharded engine must not touch the heap.
//!
//! The persistent runtime exists so that `run_rounds`/`step` reuse
//! everything round over round: parked workers, slot arenas, node-side
//! message buffers, compressor scratch (the thread-local top-k magnitude
//! and qsgd uniform buffers in `compress/ops.rs`), and the accounting
//! grid. These tests pin the claim with a counting global allocator:
//! after a short warmup (which sizes every buffer), an armed window
//! around five single-round `step()` calls must observe **zero**
//! allocations — from the driving thread and from every pool worker
//! alike (the counter is global and the workers do the actual round
//! work). One test per compressor family with its own hot path: `qsgd`
//! (quantized levels + uniform scratch) and `top_k` (sparse payload +
//! quickselect magnitude scratch).
//!
//! The claim is pinned for **both dispatch modes**: the default
//! work-stealing scheduler (per-phase atomic cursors claimed with
//! `fetch_add`, two barriers per round — the cursors live in a `Vec`
//! sized at construction and are only ever *stored to* on the hot
//! path) and the static owner-computes schedule. The node under test
//! is the compact h/e CHOCO form (`Scheme::Choco`), so the window also
//! proves the aggregate-error state update and its `add_into_state`
//! accumulation never touch the heap mid-round.
//!
//! The tests live in their own integration binary because a
//! `#[global_allocator]` is process-wide: mixing it into a shared test
//! binary would make every other test pay the (tiny) counting overhead
//! and would race other tests' allocations into the armed window. For
//! the same reason the armed windows themselves are serialized through a
//! mutex — the test harness runs `#[test]` fns on parallel threads.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use choco::compress::{Compressor, QsgdS, TopK};
use choco::consensus::{make_nodes, Scheme};
use choco::coordinator::{LinkModel, Scheduler, ShardedEngine};
use choco::topology::{uniform_local_weights, Graph};
use choco::util::rng::Rng;

/// Forwards to the system allocator, counting every allocation (and
/// growth) while armed. Frees are not counted: dropping at the end of an
/// armed window is fine, allocating inside it is the bug.
struct CountingAlloc;

static ARMED: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);
/// Serializes armed windows across tests (the counter is process-global).
static GATE: Mutex<()> = Mutex::new(());

// SAFETY: pure pass-through to the system allocator — every method
// forwards its exact arguments to `System`, which upholds the
// `GlobalAlloc` contract; the counter bump has no side effect on layout
// or pointers.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: caller upholds the `GlobalAlloc` contract; forwarded as-is.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    // SAFETY: caller upholds the `GlobalAlloc` contract; forwarded as-is.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    // SAFETY: caller upholds the `GlobalAlloc` contract; forwarded as-is.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    // SAFETY: caller upholds the `GlobalAlloc` contract; forwarded as-is.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

/// Build a 4×8 torus CHOCO run with the given operator and scheduler,
/// warm it up, then assert five steady-state rounds allocate nothing.
fn assert_steady_state_zero_alloc(op: Box<dyn Compressor>, scheduler: Scheduler) {
    let name = op.name();
    let g = Graph::torus2d(4, 8);
    let n = g.n();
    let d = 32;
    let lw = uniform_local_weights(&g);
    let mut rng = Rng::new(11);
    let x0: Vec<Vec<f64>> = (0..n)
        .map(|_| {
            let mut v = vec![0.0; d];
            rng.fill_gaussian(&mut v);
            v
        })
        .collect();
    let scheme = Scheme::Choco { gamma: 0.3, op };
    let nodes = make_nodes(&scheme, &x0, &lw);
    let mut engine =
        ShardedEngine::with_scheduler(nodes, &g, 7, LinkModel::default(), 4, scheduler);
    // Warmup: first rounds size the slot arenas, node-side message
    // buffers, thread-local compressor scratch, and the accounting grid
    // (run_rounds(3) sizes the grid for k up to 3, so the single-round
    // steps below can never outgrow it).
    engine.run_rounds(3);
    engine.step();
    let before = engine.acct.rounds;
    // Armed window: five steady-state rounds, zero heap traffic allowed.
    let gate = GATE.lock().unwrap_or_else(|e| e.into_inner());
    ALLOCS.store(0, Ordering::SeqCst);
    ARMED.store(true, Ordering::SeqCst);
    for _ in 0..5 {
        engine.step();
    }
    ARMED.store(false, Ordering::SeqCst);
    let allocs = ALLOCS.load(Ordering::SeqCst);
    drop(gate);
    assert_eq!(engine.acct.rounds, before + 5, "[{name}] engine must actually have run");
    assert!(engine.acct.bits > 0, "[{name}] rounds must move real traffic");
    assert_eq!(allocs, 0, "[{name}] steady-state rounds allocated {allocs} times; expected zero");
}

#[test]
fn steady_state_rounds_do_not_allocate_qsgd() {
    assert_steady_state_zero_alloc(Box::new(QsgdS { s: 16 }), Scheduler::Stealing);
}

#[test]
fn steady_state_rounds_do_not_allocate_topk() {
    assert_steady_state_zero_alloc(Box::new(TopK { k: 8 }), Scheduler::Stealing);
}

/// The static owner-computes schedule shares the slot arenas and node
/// buffers with the stealing path but skips the cursors and the
/// mid-round barrier — it must be just as heap-silent.
#[test]
fn steady_state_rounds_do_not_allocate_static_scheduler() {
    assert_steady_state_zero_alloc(Box::new(TopK { k: 8 }), Scheduler::Static);
}
