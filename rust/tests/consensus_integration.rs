//! Integration: every gossip scheme × operator × topology on a shared
//! consensus problem, with the paper's qualitative orderings asserted.

use choco::compress::{Compressor, QsgdS, RandK, Rescaled, TopK};
use choco::consensus::{make_nodes, Scheme, SyncRunner};
use choco::linalg::vecops;
use choco::topology::{choco_rate_bound, local_weights, mixing_matrix, Graph, MixingRule, Spectrum};
use choco::util::rng::Rng;
use choco::util::stats;

struct Problem {
    graph: Graph,
    lw: Vec<choco::topology::LocalWeights>,
    x0: Vec<Vec<f64>>,
    target: Vec<f64>,
}

fn problem(graph: Graph, d: usize, seed: u64) -> Problem {
    let n = graph.n();
    let w = mixing_matrix(&graph, MixingRule::Uniform);
    let lw = local_weights(&graph, &w);
    let mut rng = Rng::new(seed);
    let x0: Vec<Vec<f64>> = (0..n)
        .map(|_| {
            let mut v = vec![0.0; d];
            rng.fill_gaussian(&mut v);
            v
        })
        .collect();
    let target = vecops::mean_of(&x0);
    Problem { graph, lw, x0, target }
}

fn final_error(p: &Problem, scheme: Scheme, rounds: usize) -> f64 {
    let mut r = SyncRunner::new(make_nodes(&scheme, &p.x0, &p.lw), &p.graph, 7);
    for _ in 0..rounds {
        r.step();
    }
    r.error_vs(&p.target)
}

/// CHOCO converges on *every* topology with *every* operator family.
#[test]
fn choco_converges_everywhere() {
    let d = 30;
    for graph in [Graph::ring(8), Graph::torus2d(2, 4), Graph::complete(8), Graph::star(8)] {
        let p = problem(graph, d, 11);
        let cases: Vec<(Box<dyn Compressor>, f64)> = vec![
            (Box::new(TopK { k: 3 }), 0.05),
            (Box::new(RandK { k: 3 }), 0.05),
            (Box::new(QsgdS { s: 16 }), 0.3),
        ];
        for (op, gamma) in cases {
            let name = format!("{} on {}", op.name(), p.graph.name());
            let e0 = vecops::consensus_error(&p.x0, &p.target) / 8.0;
            let e = final_error(&p, Scheme::Choco { gamma, op }, 4000);
            assert!(e < e0 * 1e-5, "{name}: {e} (from {e0})");
        }
    }
}

/// Paper ordering on the hard case (fig 2/3): exact ≈ choco ≪ q2 ≤ q1.
#[test]
fn scheme_ordering_matches_paper() {
    let d = 60;
    let p = problem(Graph::ring(10), d, 3);
    let rounds = 1500;
    let e_exact = final_error(&p, Scheme::Exact { gamma: 1.0 }, rounds);
    let e_choco = final_error(
        &p,
        Scheme::Choco { gamma: 1.0, op: Box::new(QsgdS { s: 256 }) },
        rounds,
    );
    let tau = QsgdS { s: 256 }.tau(d);
    let e_q1 = final_error(
        &p,
        Scheme::Q1 { op: Box::new(Rescaled::new(QsgdS { s: 256 }, tau)) },
        rounds,
    );
    let e_q2 = final_error(
        &p,
        Scheme::Q2 { op: Box::new(Rescaled::new(QsgdS { s: 256 }, tau)) },
        rounds,
    );
    assert!(e_exact < 1e-20);
    assert!(e_choco < 1e-12, "choco {e_choco}");
    assert!(e_q2 > e_choco * 1e3, "q2 {e_q2} vs choco {e_choco}");
    assert!(e_q1 > e_choco * 1e3, "q1 {e_q1} vs choco {e_choco}");
}

/// Theorem 2's rate bound holds with the theoretical γ* across operators
/// and topologies (measured contraction ≤ bound).
#[test]
fn thm2_bound_across_configs() {
    for (graph, d) in [(Graph::ring(6), 16usize), (Graph::torus2d(2, 3), 12)] {
        let p = problem(graph, d, 9);
        let w = mixing_matrix(&p.graph, MixingRule::Uniform);
        let spec = Spectrum::of(&w).unwrap();
        for op in [
            Box::new(RandK { k: 2 }) as Box<dyn Compressor>,
            Box::new(TopK { k: 2 }),
        ] {
            let omega = op.omega(d);
            let gamma = choco::topology::choco_gamma_star(spec.delta, spec.beta, omega).unwrap();
            let name = format!("{} on {}", op.name(), p.graph.name());
            let mut r = SyncRunner::new(
                make_nodes(&Scheme::Choco { gamma, op }, &p.x0, &p.lw),
                &p.graph,
                5,
            );
            let mut errs = vec![r.error_vs(&p.target)];
            for _ in 0..2000 {
                r.step();
                errs.push(r.error_vs(&p.target));
            }
            let measured = stats::contraction_factor(&errs);
            let bound = choco_rate_bound(spec.delta, omega);
            assert!(measured <= bound + 1e-4, "{name}: {measured} > {bound}");
        }
    }
}

/// Per-bit efficiency (fig 3 right panel): at equal transmitted bits,
/// CHOCO+rand1% reaches an error in the same decade as exact gossip.
#[test]
fn per_bit_efficiency() {
    let d = 100;
    let p = problem(Graph::ring(8), d, 21);
    // exact: 200 rounds at 32d bits per message
    let mut exact = SyncRunner::new(
        make_nodes(&Scheme::Exact { gamma: 1.0 }, &p.x0, &p.lw),
        &p.graph,
        3,
    );
    let mut exact_bits = 0u64;
    for _ in 0..150 {
        exact_bits += exact.step().bits;
    }
    // choco rand_10% with the same bit budget
    let op = RandK { k: 10 };
    let mut choco = SyncRunner::new(
        make_nodes(&Scheme::Choco { gamma: 0.05, op: Box::new(op) }, &p.x0, &p.lw),
        &p.graph,
        3,
    );
    let mut choco_bits = 0u64;
    let mut rounds = 0;
    while choco_bits < exact_bits {
        choco_bits += choco.step().bits;
        rounds += 1;
        assert!(rounds < 500_000, "runaway");
    }
    let e_exact = exact.error_vs(&p.target);
    let e_choco = choco.error_vs(&p.target);
    // both should have made enormous progress; choco within ~6 orders
    // (the seed overhead + γ tuning cost it some per-bit efficiency at
    // this tiny scale).
    let e0 = vecops::consensus_error(&p.x0, &p.target) / 8.0;
    assert!(e_exact < e0 * 1e-10);
    assert!(e_choco < e0 * 1e-4, "choco per-bit too weak: {e_choco} vs start {e0}");
}

/// Disconnected graphs have δ = 0 and gossip must not reach global
/// consensus (sanity check on the spectral precondition).
#[test]
fn disconnected_graph_never_converges() {
    let d = 10;
    let graph = Graph::disconnected(4);
    let n = graph.n();
    let w = mixing_matrix(&graph, MixingRule::Uniform);
    let spec = Spectrum::of(&w).unwrap();
    assert!(spec.delta.abs() < 1e-9);
    let lw = local_weights(&graph, &w);
    let mut rng = Rng::new(5);
    let x0: Vec<Vec<f64>> = (0..n)
        .map(|_| {
            let mut v = vec![0.0; d];
            rng.fill_gaussian(&mut v);
            v
        })
        .collect();
    let target = vecops::mean_of(&x0);
    let mut r = SyncRunner::new(
        make_nodes(&Scheme::Exact { gamma: 1.0 }, &x0, &lw),
        &graph,
        3,
    );
    for _ in 0..500 {
        r.step();
    }
    let e = r.error_vs(&target);
    assert!(e > 1e-6, "disconnected graph should not reach global average, got {e}");
}
