//! End-to-end acceptance for the wire-codec subsystem: the actor runtime
//! in `serialize: true` mode ships codec frames whose measured size is
//! within 5% of the operators' idealized `wire_bits` at d = 10⁴ — the
//! regime where the legacy serializer (full f32 vectors for quantized
//! payloads) diverged ~8–32× from the claims.

use choco::compress::{codec, Compressor, QsgdS, ScaledSign};
use choco::consensus::{make_nodes, Scheme};
use choco::coordinator::{run_actors, ActorConfig};
use choco::topology::{local_weights, mixing_matrix, Graph, MixingRule};
use choco::util::rng::Rng;

/// Run CHOCO over a 4-ring through real serialized channels and return
/// (measured bits, idealized bits).
fn measured_vs_idealized(scheme: Scheme, d: usize, rounds: usize) -> (u64, u64) {
    let n = 4;
    let g = Graph::ring(n);
    let w = mixing_matrix(&g, MixingRule::Uniform);
    let lw = local_weights(&g, &w);
    let mut rng = Rng::new(7);
    let x0: Vec<Vec<f64>> = (0..n)
        .map(|_| {
            let mut v = vec![0.0; d];
            rng.fill_gaussian(&mut v);
            v
        })
        .collect();
    let cfg = ActorConfig { rounds, seed: 5, serialize: true, ..Default::default() };
    let r = run_actors(make_nodes(&scheme, &x0, &lw), &g, &cfg).unwrap();
    assert!(r.bits > 0 && r.idealized_bits > 0);
    (r.bits, r.idealized_bits)
}

fn assert_within_5_percent(measured: u64, idealized: u64, what: &str) {
    assert!(
        measured >= idealized,
        "{what}: measured {measured} below idealized {idealized} — claims are now understated"
    );
    let ratio = measured as f64 / idealized as f64;
    assert!(
        ratio <= 1.05,
        "{what}: measured {measured} vs idealized {idealized} bits (ratio {ratio:.4})"
    );
}

#[test]
fn qsgd16_actor_frames_within_5_percent_of_idealized_at_d10k() {
    let d = 10_000;
    let scheme = Scheme::Choco { gamma: 0.2, op: Box::new(QsgdS { s: 16 }) };
    let (measured, idealized) = measured_vs_idealized(scheme, d, 3);
    assert_within_5_percent(measured, idealized, "choco + qsgd_16");
}

#[test]
fn scaled_sign_actor_frames_within_5_percent_of_idealized_at_d10k() {
    let d = 10_000;
    let scheme = Scheme::Choco { gamma: 0.2, op: Box::new(ScaledSign) };
    let (measured, idealized) = measured_vs_idealized(scheme, d, 3);
    assert_within_5_percent(measured, idealized, "choco + sign");
}

/// The same guarantee at the single-frame level, with exact expected
/// sizes: quantized frames cost claimed + 96 bits (header + width byte),
/// sign frames claimed + 88 bits (header).
#[test]
fn single_frame_overhead_is_exactly_the_header() {
    let d = 10_000;
    let mut rng = Rng::new(11);
    let mut x = vec![0.0; d];
    rng.fill_gaussian(&mut x);

    let c = QsgdS { s: 16 }.compress(&x, &mut rng);
    assert_eq!(c.wire_bits, (1 + 4) * d as u64 + 32);
    assert_eq!(codec::encoded_bits(&c), c.wire_bits + codec::HEADER_BITS + 8);

    let c = ScaledSign.compress(&x, &mut rng);
    assert_eq!(c.wire_bits, d as u64 + 32);
    assert_eq!(codec::encoded_bits(&c), c.wire_bits + codec::HEADER_BITS);
}

/// Value-mode equivalence (the other half of the acceptance criterion) is
/// pinned by `actor_matches_round_engine_exactly_in_value_mode` in
/// `coordinator::actor`; here we check serialization itself no longer
/// perturbs quantized trajectories at all — scales are f32-narrowed at
/// compression time, so frames are bit-exact.
#[test]
fn serialized_qsgd_trajectories_match_value_mode_bit_exactly() {
    let n = 5;
    let d = 64;
    let g = Graph::ring(n);
    let w = mixing_matrix(&g, MixingRule::Uniform);
    let lw = local_weights(&g, &w);
    let mut rng = Rng::new(23);
    let x0: Vec<Vec<f64>> = (0..n)
        .map(|_| {
            let mut v = vec![0.0; d];
            rng.fill_gaussian(&mut v);
            v
        })
        .collect();
    let run = |serialize: bool| {
        let scheme = Scheme::Choco { gamma: 0.3, op: Box::new(QsgdS { s: 16 }) };
        let cfg = ActorConfig { rounds: 25, seed: 9, serialize, ..Default::default() };
        run_actors(make_nodes(&scheme, &x0, &lw), &g, &cfg).unwrap()
    };
    let a = run(true);
    let b = run(false);
    for (xa, xb) in a.iterates.iter().zip(b.iterates.iter()) {
        assert_eq!(xa, xb, "serialization perturbed a quantized trajectory");
    }
}
