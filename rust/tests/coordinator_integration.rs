//! Integration: the two runtimes (round engine vs threaded actors) agree,
//! accounting is exact, and failure injection behaves as documented.

use choco::compress::{Compressed, Payload, QsgdS, TopK};
use choco::consensus::{make_nodes, GossipNode, Scheme};
use choco::coordinator::{
    run_actors, ActorConfig, AsyncConfig, EventEngine, LinkModel, RoundConfig, RoundEngine,
    ShardedEngine,
};
use choco::linalg::vecops;
use choco::optim::{make_optim_nodes, NativeGrad, OptimScheme, Schedule};
use choco::topology::{local_weights, mixing_matrix, Graph, MixingRule};
use choco::util::rng::Rng;

fn x0s(n: usize, d: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let x0: Vec<Vec<f64>> = (0..n)
        .map(|_| {
            let mut v = vec![0.0; d];
            rng.fill_gaussian(&mut v);
            v
        })
        .collect();
    let target = vecops::mean_of(&x0);
    (x0, target)
}

/// Round engine and actor runtime produce identical trajectories for the
/// same seeds (value mode), for consensus AND optimizer nodes.
#[test]
fn runtimes_agree_consensus_and_sgd() {
    // consensus
    let g = Graph::torus2d(2, 3);
    let w = mixing_matrix(&g, MixingRule::Uniform);
    let lw = local_weights(&g, &w);
    let (x0, _) = x0s(6, 12, 3);
    let scheme = Scheme::Choco { gamma: 0.2, op: Box::new(QsgdS { s: 16 }) };
    let rounds = 50;
    let mut engine =
        RoundEngine::new(make_nodes(&scheme, &x0, &lw), &g, 17, LinkModel::default());
    for _ in 0..rounds {
        engine.step();
    }
    let actors = run_actors(
        make_nodes(&scheme, &x0, &lw),
        &g,
        &ActorConfig { rounds, seed: 17, serialize: false, ..Default::default() },
    )
    .unwrap();
    for (a, b) in engine.iterates().iter().zip(actors.iterates.iter()) {
        assert_eq!(vecops::max_abs_diff(a, b), 0.0, "consensus trajectories differ");
    }

    // optimizer (CHOCO-SGD on quadratic objectives)
    let mk_sources = || {
        (0..6)
            .map(|i| {
                Box::new(NativeGrad {
                    objective: Box::new(choco::models::QuadraticConsensus::new(
                        vec![i as f64; 12],
                        0.5,
                    )),
                }) as Box<dyn choco::optim::GradientSource>
            })
            .collect::<Vec<_>>()
    };
    let opt_scheme = OptimScheme::ChocoSgd {
        schedule: Schedule::Const(0.05),
        gamma: 0.3,
        op: Box::new(TopK { k: 3 }),
    };
    let mut engine = RoundEngine::new(
        make_optim_nodes(&opt_scheme, mk_sources(), &x0, &lw),
        &g,
        23,
        LinkModel::default(),
    );
    for _ in 0..rounds {
        engine.step();
    }
    let actors = run_actors(
        make_optim_nodes(&opt_scheme, mk_sources(), &x0, &lw),
        &g,
        &ActorConfig { rounds, seed: 23, serialize: false, ..Default::default() },
    )
    .unwrap();
    for (a, b) in engine.iterates().iter().zip(actors.iterates.iter()) {
        assert_eq!(vecops::max_abs_diff(a, b), 0.0, "SGD trajectories differ");
    }
}

/// Bits accounting matches the closed-form prediction for every scheme.
#[test]
fn bit_accounting_exact() {
    let n = 8;
    let d = 100;
    let g = Graph::ring(n);
    let w = mixing_matrix(&g, MixingRule::Uniform);
    let lw = local_weights(&g, &w);
    let (x0, _) = x0s(n, d, 5);
    let rounds = 10u64;

    // exact: per round n·deg·32d
    let mut engine = RoundEngine::new(
        make_nodes(&Scheme::Exact { gamma: 1.0 }, &x0, &lw),
        &g,
        1,
        LinkModel::default(),
    );
    for _ in 0..rounds {
        engine.step();
    }
    assert_eq!(engine.acct.bits, rounds * (n as u64) * 2 * 32 * d as u64);

    // choco qsgd_16: per round n·deg·((1+4)d + 32) — the paper's 4 bits
    // per coordinate plus the sign bit the wire actually ships
    let mut engine = RoundEngine::new(
        make_nodes(&Scheme::Choco { gamma: 0.3, op: Box::new(QsgdS { s: 16 }) }, &x0, &lw),
        &g,
        1,
        LinkModel::default(),
    );
    for _ in 0..rounds {
        engine.step();
    }
    assert_eq!(engine.acct.bits, rounds * (n as u64) * 2 * (5 * d as u64 + 32));
}

/// Simulated time follows the link model: halving bandwidth increases the
/// BSP round time accordingly for full-vector messages.
#[test]
fn sim_time_scales_with_bandwidth() {
    let g = Graph::ring(6);
    let w = mixing_matrix(&g, MixingRule::Uniform);
    let lw = local_weights(&g, &w);
    let (x0, _) = x0s(6, 1000, 7);
    let time_at = |bw: f64| {
        let link = LinkModel { latency_s: 0.0, bandwidth_bps: bw, drop_prob: 0.0 };
        let mut e = RoundEngine::new(
            make_nodes(&Scheme::Exact { gamma: 1.0 }, &x0, &lw),
            &g,
            1,
            link,
        );
        for _ in 0..5 {
            e.step();
        }
        e.acct.sim_time_s
    };
    let t_fast = time_at(1e9);
    let t_slow = time_at(5e8);
    assert!((t_slow / t_fast - 2.0).abs() < 1e-6, "ratio {}", t_slow / t_fast);
}

/// Failure injection: increasing drop rates monotonically degrade CHOCO's
/// achievable accuracy (replica desync), while 0% matches the clean run.
#[test]
fn drop_rate_degrades_choco_monotonically() {
    let g = Graph::ring(8);
    let w = mixing_matrix(&g, MixingRule::Uniform);
    let lw = local_weights(&g, &w);
    let (x0, target) = x0s(8, 40, 9);
    let err_at = |p: f64| {
        let link = LinkModel { drop_prob: p, ..Default::default() };
        let mut e = RoundEngine::new(
            make_nodes(
                &Scheme::Choco { gamma: 0.2, op: Box::new(TopK { k: 4 }) },
                &x0,
                &lw,
            ),
            &g,
            13,
            link,
        );
        for _ in 0..2000 {
            e.step();
        }
        e.iterates().iter().map(|x| vecops::dist_sq(x, &target)).sum::<f64>() / 8.0
    };
    let clean = err_at(0.0);
    let light = err_at(0.02);
    let heavy = err_at(0.2);
    assert!(clean < 1e-10, "clean run should converge: {clean}");
    assert!(light > clean, "2% loss should hurt: {light} vs {clean}");
    assert!(heavy > light * 0.1, "20% loss at least comparable to 2%: {heavy} vs {light}");
    assert!(heavy.is_finite());
}

/// Serialized actor mode ships decodable bytes and stays numerically close
/// to value mode over optimizer rounds.
#[test]
fn serialization_end_to_end_sgd() {
    let g = Graph::ring(5);
    let w = mixing_matrix(&g, MixingRule::Uniform);
    let lw = local_weights(&g, &w);
    let (x0, _) = x0s(5, 16, 11);
    let mk_sources = || {
        (0..5)
            .map(|i| {
                Box::new(NativeGrad {
                    objective: Box::new(choco::models::QuadraticConsensus::new(
                        vec![(i as f64) / 2.0; 16],
                        0.1,
                    )),
                }) as Box<dyn choco::optim::GradientSource>
            })
            .collect::<Vec<_>>()
    };
    let scheme = || OptimScheme::ChocoSgd {
        schedule: Schedule::Const(0.1),
        gamma: 0.4,
        op: Box::new(TopK { k: 2 }),
    };
    let a = run_actors(
        make_optim_nodes(&scheme(), mk_sources(), &x0, &lw),
        &g,
        &ActorConfig { rounds: 60, seed: 2, serialize: true, ..Default::default() },
    )
    .unwrap();
    let b = run_actors(
        make_optim_nodes(&scheme(), mk_sources(), &x0, &lw),
        &g,
        &ActorConfig { rounds: 60, seed: 2, serialize: false, ..Default::default() },
    )
    .unwrap();
    for (xa, xb) in a.iterates.iter().zip(b.iterates.iter()) {
        assert!(vecops::max_abs_diff(xa, xb) < 1e-3);
    }
    assert!(a.bits > 0);
}

/// RoundEngine's `run` stops on divergence and reports a truncated trace
/// rather than panicking.
#[test]
fn engine_survives_divergence() {
    let g = Graph::ring(6);
    let w = mixing_matrix(&g, MixingRule::Uniform);
    let lw = local_weights(&g, &w);
    let (x0, _) = x0s(6, 10, 15);
    // ECD with a harsh operator at a large stepsize diverges fast.
    let sources = (0..6)
        .map(|_| {
            Box::new(NativeGrad {
                objective: Box::new(choco::models::QuadraticConsensus::new(vec![1.0; 10], 1.0)),
            }) as Box<dyn choco::optim::GradientSource>
        })
        .collect();
    let scheme = OptimScheme::Ecd {
        schedule: Schedule::Const(0.8),
        op: Box::new(choco::compress::Rescaled::new(choco::compress::RandK { k: 1 }, 10.0)),
    };
    let mut engine = RoundEngine::new(
        make_optim_nodes(&scheme, sources, &x0, &lw),
        &g,
        1,
        LinkModel::default(),
    );
    let cfg = RoundConfig { rounds: 5000, log_every: 10, ..Default::default() };
    let trace = engine.run("ecd", &cfg, Box::new(|nodes| {
        nodes.iter().map(|n| vecops::norm2_sq(n.x())).sum::<f64>()
    }));
    // either finished or stopped early on a non-finite metric; both fine,
    // but the trace must exist and all logged rows be ordered.
    let iters = trace.column("iter");
    assert!(iters.windows(2).all(|w| w[1] > w[0]));
}

/// The actor runtime's thread-cap guard, driven from the event runtime's
/// config type: a population the actor runtime refuses (n > max_threads)
/// runs fine — and trajectory-equal to the serial oracle — on the event
/// engine, which needs one thread regardless of n.
#[test]
fn actor_cap_refusal_names_the_alternatives_event_engine_accepts() {
    let g = Graph::ring(8);
    let w = mixing_matrix(&g, MixingRule::Uniform);
    let lw = local_weights(&g, &w);
    let (x0, _) = x0s(8, 6, 19);
    let scheme = || Scheme::Choco { gamma: 0.2, op: Box::new(TopK { k: 2 }) };
    let cfg = AsyncConfig::bsp_equivalent(25, 21);

    let err = run_actors(
        make_nodes(&scheme(), &x0, &lw),
        &g,
        &ActorConfig { rounds: cfg.rounds, seed: cfg.seed, max_threads: 4, ..Default::default() },
    )
    .unwrap_err();
    assert!(err.contains("8 nodes"), "error should name the node count: {err}");
    assert!(err.contains("max_threads"), "error should name the knob: {err}");
    assert!(err.contains("ShardedEngine"), "error should point at the large-n runtime: {err}");

    // the same population and seed, single-threaded on the event queue
    let mut event = EventEngine::new(make_nodes(&scheme(), &x0, &lw), &g, cfg.clone());
    event.run();
    let mut serial =
        RoundEngine::new(make_nodes(&scheme(), &x0, &lw), &g, cfg.seed, cfg.link.clone());
    for _ in 0..cfg.rounds {
        serial.step();
    }
    for (a, b) in event.iterates().iter().zip(serial.iterates().iter()) {
        assert_eq!(vecops::max_abs_diff(a, b), 0.0, "event engine drifted from serial");
    }
}

/// A node that behaves until a chosen round, then panics in its broadcast
/// phase — exercising the sharded engine's worker panic guard.
struct PanicNode {
    id: usize,
    x: Vec<f64>,
}

impl GossipNode for PanicNode {
    fn dim(&self) -> usize {
        self.x.len()
    }
    fn begin_round(&mut self, t: usize, _rng: &mut Rng) -> Compressed {
        if t >= 2 && self.id == 5 {
            panic!("injected worker panic at round {t}");
        }
        Compressed { dim: self.x.len(), payload: Payload::Dense(self.x.clone()), wire_bits: 64 }
    }
    fn receive(&mut self, _from: usize, _msg: &Compressed) {}
    fn end_round(&mut self, _t: usize) {}
    fn x(&self) -> &[f64] {
        &self.x
    }
}

/// A panic on a worker thread must resurface on the caller thread with
/// its original payload — not deadlock the barrier or get swallowed.
#[test]
fn sharded_engine_rethrows_worker_panics() {
    let g = Graph::ring(8);
    // rounds/seed drawn from an event-runtime config, per the shared
    // population-sizing convention
    let cfg = AsyncConfig::bsp_equivalent(5, 1);
    let nodes: Vec<Box<dyn GossipNode>> = (0..8)
        .map(|i| Box::new(PanicNode { id: i, x: vec![0.0; 4] }) as Box<dyn GossipNode>)
        .collect();
    let mut e = ShardedEngine::with_shards(nodes, &g, cfg.seed, cfg.link.clone(), 4);
    let result =
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| e.run_rounds(cfg.rounds)));
    assert!(result.is_err(), "worker panic must propagate to the caller");
    let payload = result.unwrap_err();
    let msg = payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default();
    assert!(msg.contains("injected worker panic"), "panic payload lost: {msg:?}");
}
